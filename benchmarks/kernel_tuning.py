"""Seg-scan kernel tuning microbenchmarks -> BENCH_kernel.json.

Measures the DES scan hot path end-to-end (``simulate_completion_scan``)
per execution path — lax baseline, v2 fused kernel per candidate chunk,
v2 at the roofline-autotuned chunk — plus the legacy v1 matmul kernel in
isolation, and records the autotuner's analytic ranking next to the
measured times (maxtext-microbenchmark style: cached jitted callables,
best-of-repeats walls).

Off-TPU every kernel number is the INTERPRET/EMULATION fallback, never a
compiled accelerator kernel; the payload carries ``kernel_path`` so the
provenance is explicit (satellite of the one-time
``KernelInterpretFallbackWarning``).  The v1 kernel runs under the actual
Pallas interpreter, which pays per-grid-step Python overhead, so it is
measured at a smaller size and labelled with its own ``n_cloudlets``.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, smoke, timed
from repro.core.compat import kernel_path
from repro.core.des_scan import simulate_completion_scan_jit
from repro.roofline import autotune

BENCH_JSON = "BENCH_kernel.json"


def _scan_inputs(C, V, seed=0):
    rng = np.random.default_rng(seed)
    assign = jnp.asarray(rng.integers(0, V, C).astype(np.int32))
    mi = jnp.asarray(rng.uniform(1e3, 5e4, C).astype(np.float32))
    mips = jnp.asarray(rng.uniform(500.0, 2000.0, V).astype(np.float32))
    valid = jnp.asarray(rng.uniform(size=C) < 0.97)
    return assign, mi, mips, valid


def _scan_entry(core, C, t, chunk=None, **extra):
    e = {"core": core, "n_cloudlets": int(C), "scan_s": float(t), **extra}
    if chunk is not None:
        e["chunk"] = int(chunk)
    emit(f"kernel/{core.split('/', 1)[1]}_C{C}"
         + (f"_chunk{chunk}" if chunk is not None else ""), t * 1e6,
         extra.get("derived", ""))
    return e


def main():
    sizes = [4096] if smoke() else [65536, 1 << 20]
    chunks = (64, 128) if smoke() else (64, 128, 256)
    v1_size = 1024 if smoke() else 16384
    path = kernel_path(True)
    entries = []

    for C in sizes:
        V = max(C // 16, 4)
        args = _scan_inputs(C, V)

        t_lax, (f_lax, _) = timed(
            lambda: simulate_completion_scan_jit(*args), repeats=3)
        entries.append(_scan_entry("kernel/lax", C, t_lax))

        for chunk in chunks:
            t_k, (f_k, _) = timed(
                lambda c=chunk: simulate_completion_scan_jit(
                    *args, use_kernel=True, kernel_chunk=c), repeats=3)
            assert np.array_equal(np.asarray(f_lax), np.asarray(f_k)), (
                "v2 fused path lost bit-identity at "
                f"C={C} chunk={chunk}")
            entries.append(_scan_entry(
                "kernel/v2_fused", C, t_k, chunk=chunk,
                derived=f"x{t_lax / t_k:.2f}_vs_lax"))

        tuned = autotune.tuned_chunk(C, measure=True)
        t_t, (f_t, _) = timed(
            lambda: simulate_completion_scan_jit(
                *args, use_kernel=True, kernel_chunk=tuned), repeats=3)
        assert np.array_equal(np.asarray(f_lax), np.asarray(f_t))
        entries.append(_scan_entry(
            "kernel/v2_tuned", C, t_t, chunk=tuned,
            derived=f"x{t_lax / t_t:.2f}_vs_lax"))

    # legacy v1 kernel in isolation (tolerance-equivalent; actual Pallas
    # interpreter off-TPU, hence the smaller size)
    from repro.kernels.seg_scan.ops import segmented_cumsum, segmented_cumsum_v2

    rng = np.random.default_rng(1)
    term = jnp.asarray(rng.uniform(0, 5, v1_size).astype(np.float32))
    start = jnp.asarray(rng.uniform(size=v1_size) < 0.1)
    for chunk in chunks:
        t_v1, _ = timed(segmented_cumsum, term, start.astype(jnp.float32),
                        chunk=chunk, repeats=2)
        entries.append(_scan_entry("kernel/v1", v1_size, t_v1, chunk=chunk))
        t_v2, _ = timed(segmented_cumsum_v2, term, start, chunk=chunk,
                        repeats=2)
        entries.append(_scan_entry("kernel/v2", v1_size, t_v2, chunk=chunk,
                                   derived=f"x{t_v1 / t_v2:.1f}_vs_v1"))

    ranking = [
        {"chunk": s.chunk, "t_model_s": s.t_model, "bottleneck": s.bottleneck}
        for s in autotune.rank_chunks(sizes[-1])]
    choice = autotune.tuning_report(sizes[-1])
    return {
        "backend": jax.default_backend(),
        "kernel_path": path,
        "note": ("kernel timings are interpret/emulation-mode (no TPU in "
                 "this environment) — NOT compiled-kernel performance"
                 if path == "interpret" else "compiled Pallas kernels"),
        "autotuner": {
            "analytic_ranking": ranking,
            "choice": None if choice is None else {
                "chunk": choice.chunk, "source": choice.source,
                "measured_s": {str(k): v
                               for k, v in choice.measured_s.items()}},
        },
        "entries": entries,
    }


if __name__ == "__main__":
    main()
