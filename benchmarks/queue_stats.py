"""Queueing observability — per-stage latency percentiles of the pipeline.

Streams the scenario-grid workload through an instrumented dispatcher
(``collect_stats=True``) and records, per (chunk, members) cell, the
decomposed latency percentiles the stats layer measures — queue wait and
service p50/p99, utilization, time-averaged queue length — alongside the
total wall (``scan_s``, so ``run.py --check`` gates the instrumented path
against the committed ``BENCH_queue.json`` like every other benchmark; the
percentile fields are informational).
"""
import json
import os
import sys

if __package__ in (None, ""):   # standalone: python benchmarks/queue_stats.py
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    _root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import jax
import numpy as np

from benchmarks.common import emit, smoke
from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid

BENCH_JSON = "BENCH_queue.json"


def _make(B: int, n_vms: int, n_cloudlets: int):
    cfg = SimulationConfig(n_vms=n_vms, n_cloudlets=n_cloudlets)
    grid = make_scenario_grid(
        seeds=range(max(1, -(-B // 8))), mi_scales=[0.75, 1.5],
        vm_counts=[n_vms // 2, n_vms], mips_dists=["uniform", "fixed"])
    grid = {k: np.asarray(v)[:B] for k, v in grid.items()}
    assert len(grid["seeds"]) == B
    return cfg, grid


def bench_cell(B, chunk, members, n_vms, n_cloudlets, reps=3):
    """One (chunk, members) cell: best-of-``reps`` wall with the collector
    on, plus that run's measured stage decomposition."""
    from repro.core.dispatch import ElasticDispatcher
    cfg, grid = _make(B, n_vms, n_cloudlets)
    d = ElasticDispatcher(devices=jax.devices()[:members],
                          start_members=members, dispatch_ahead=4,
                          collect_stats=True)
    run_scenario_grid(cfg, grid, dispatcher=d, chunk=chunk)   # compile
    best = None
    for _ in range(reps):
        r = run_scenario_grid(cfg, grid, dispatcher=d, chunk=chunk)
        w = r.timings["batch_total"]
        if best is None or w < best[0]:
            best = (w, r.dispatch["stats"])
    wall, stats = best
    q = stats["queue"]
    entry = {"core": "queue_stats", "n_scenarios": B, "n_vms": n_vms,
             "n_cloudlets": n_cloudlets, "n_members": members,
             "chunk": chunk, "scan_s": wall,
             "queue_wait_p50": stats["queue_wait"]["p50"],
             "queue_wait_p99": stats["queue_wait"]["p99"],
             "service_p50": stats["service"]["p50"],
             "service_p99": stats["service"]["p99"],
             "utilization": q["utilization"],
             "mean_queue_length": q["mean_queue_length"],
             "throughput": q["throughput"]}
    emit(f"queue/c{chunk}/M{members}", wall * 1e6,
         f"svc_p50={stats['service']['p50'] * 1e6:.0f}us "
         f"wait_p99={stats['queue_wait']['p99'] * 1e6:.0f}us")
    return entry


def main():
    if smoke():
        B, n_vms, n_cl, chunks = 8, 16, 200, (2, 4)
    else:
        B, n_vms, n_cl, chunks = 64, 64, 1_000, (8, 32)
    n_dev = len(jax.devices())
    member_counts = sorted({1, min(8, n_dev)})
    entries = [bench_cell(B, chunk, m, n_vms, n_cl)
               for chunk in chunks for m in member_counts]
    return {"n_devices": n_dev, "entries": entries}


if __name__ == "__main__":
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         BENCH_JSON)
    with open(_path, "w") as f:
        json.dump(main(), f, indent=2)
    print(f"wrote {_path}")
