"""Durable dispatch — checkpoint overhead + resume-vs-rerun latency.

Two measurements on a deterministic-sum chunk stream (the float reduction
whose pow2-aligned binary-counter state is what the journal checkpoints),
written to ``BENCH_resume.json``:

* ``overhead``: the fault-free async stream with NO journal vs a journaled
  stream at ``every_n_chunks`` ∈ {1, 4, 16}, measured PAIRED — all modes
  alternate rep by rep in ABBA order so each samples the same box state,
  each keeps its best.  The per-chunk gather + digest + checkpoint fold all
  ride the journal writer thread (``JobJournal.defer``), so the dispatch
  thread pays one queue put per chunk; the workload computes like a real
  DES-scan stream so that CPU ratio is the one that matters.  All walls
  are ``scan_s`` entries (labelled by ``core``), so
  ``run.py --check`` gates them like every other benchmark; the PR
  acceptance pins the ``every_n_chunks=4`` overhead at ≤ 5%.
* ``resume``: a journaled stream killed at ¾ of its chunks, then the
  measured ``ElasticDispatcher.resume`` wall vs rerunning the whole stream
  from scratch — the durability payoff.  Latency entries are informational
  (they depend on the kill point), not regression-gated; bit-identity of
  the resumed bytes IS asserted.
"""
import json
import os
import shutil
import sys
import tempfile
import time

if __package__ in (None, ""):   # standalone: python benchmarks/checkpoint_resume.py
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    _root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, smoke
from repro.core.dispatch import DispatchJob, ElasticDispatcher
from repro.core.faults import CoordinatorCrashError, FaultInjector, FaultSpec
from repro.core.journal import CheckpointPolicy

BENCH_JSON = "BENCH_resume.json"


def _job():
    # an iterated map (128 sqrt steps/row, ~1.5µs/item) calibrated to the
    # per-item compute of the repo's real DES-scan streams (BENCH_core.json:
    # ~1.1µs/cloudlet, BENCH_dist.json: ~1.9µs) — checkpoint overhead is
    # only meaningful relative to a workload that computes, and on a
    # single-core box the journal's gather+digest CPU can't hide behind a
    # memcpy-speed member fn
    def member_fn(x, v, w):
        def step(_, y):
            return y * np.float32(0.995) + jnp.sqrt(jnp.abs(y) + w)
        return jax.lax.fori_loop(0, 128, step, x)

    return DispatchJob(name="det", signature="bench-resume", reduce="sum",
                       deterministic=True, member_fn=member_fn)


def _items(C):
    rng = np.random.RandomState(0)
    return (rng.randn(C, 8) * 10 ** rng.uniform(-2, 2, (C, 8))).astype(
        np.float32)


def _dispatcher(members):
    return ElasticDispatcher(devices=jax.devices()[:members],
                             start_members=members, dispatch_ahead=2)


def bench_overhead(C, chunk, members, reps, workdir):
    """No-journal vs every_n_chunks ∈ {1,4,16}, paired ABBA best-of."""
    job, items, w = _job(), _items(C), np.float32(1.7)
    modes = {"ckpt_none": None, "ckpt_every1": 1, "ckpt_every4": 4,
             "ckpt_every16": 16}
    disp = {m: _dispatcher(members) for m in modes}

    def run(m):
        every = modes[m]
        pol = (None if every is None else
               CheckpointPolicy(path=os.path.join(workdir, m),
                                every_n_chunks=every))
        t0 = time.perf_counter()
        out, _ = disp[m].submit(job, items, replicated=(w,), chunk=chunk,
                                deliver="host", checkpoint=pol)
        return time.perf_counter() - t0, np.asarray(out)

    best, ref = {}, None
    for m in disp:                         # compile everything first
        _, out = run(m)
        if ref is None:
            ref = out
        assert out.tobytes() == ref.tobytes(), m   # journaling never
        # changes the bytes
    for rep in range(reps):
        order = list(disp) if rep % 2 == 0 else list(disp)[::-1]
        for m in order:
            wall, _ = run(m)
            if m not in best or wall < best[m]:
                best[m] = wall
    entries = [{"core": m, "n_scenarios": C, "n_members": members,
                "chunk": chunk, "every_n_chunks": modes[m],
                "scan_s": best[m]} for m in disp]
    overheads = {m: best[m] / best["ckpt_none"] - 1.0
                 for m in disp if m != "ckpt_none"}
    for e in entries:
        emit(f"ckpt/{e['core']}/C{C}", e["scan_s"] * 1e6,
             f"{C / e['scan_s']:.0f} items/s")
    for m, ov in overheads.items():
        emit(f"ckpt/overhead/{m}", ov * 1e6, f"{ov * 100:+.2f}%")
    return {"entries": entries,
            "overhead_pct": {m: ov * 100.0 for m, ov in overheads.items()}}


def bench_resume(C, chunk, members, workdir):
    """Kill a journaled stream at ¾ of its chunks; resume wall vs rerun
    wall.  The resumed bytes must equal the uninterrupted run's."""
    job, items, w = _job(), _items(C), np.float32(1.7)
    n_chunks = -(-C // chunk)
    kill_at = max(1, (3 * n_chunks) // 4)
    ck = os.path.join(workdir, "resume")

    # rerun baseline: a FRESH dispatcher paying its own compile, exactly
    # like the post-crash choice really looks (the dead coordinator's cache
    # died with it) — resume below starts equally cold
    d0 = _dispatcher(members)
    t0 = time.perf_counter()
    out_ref, _ = d0.submit(job, items, replicated=(w,), chunk=chunk,
                           deliver="host")
    rerun_s = time.perf_counter() - t0

    d1 = _dispatcher(members)
    try:
        d1.submit(job, items, replicated=(w,), chunk=chunk, deliver="host",
                  checkpoint=CheckpointPolicy(path=ck, every_n_chunks=4),
                  fault_injector=FaultInjector(
                      [FaultSpec("coordinator_crash", chunk=kill_at)]))
        raise RuntimeError("coordinator_crash did not fire")
    except CoordinatorCrashError:
        pass

    d2 = _dispatcher(members)
    t0 = time.perf_counter()
    out, rep = d2.resume(ck, job, items, replicated=(w,), chunk=chunk)
    resume_s = time.perf_counter() - t0
    assert np.asarray(out).tobytes() == np.asarray(out_ref).tobytes()

    entry = {"n_scenarios": C, "n_members": members, "chunk": chunk,
             "n_chunks": n_chunks, "kill_at": kill_at,
             "chunks_skipped": rep.chunks_skipped,
             "chunks_replayed": rep.chunks_replayed,
             "resume_s": resume_s, "rerun_s": rerun_s,
             "speedup": rerun_s / max(resume_s, 1e-9)}
    emit(f"ckpt/resume/C{C}", resume_s * 1e6,
         f"vs rerun {rerun_s * 1e6:.0f}us "
         f"(skipped {rep.chunks_skipped}/{n_chunks})")
    return entry


def main():
    if smoke():
        C, chunk, reps = 2_048, 256, 2
    else:
        C, chunk, reps = 200_000, 8_192, 6
    members = len(jax.devices())
    workdir = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        overhead = bench_overhead(C, chunk, members, reps, workdir)
        resume = bench_resume(C, chunk, members, workdir)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    return {"n_devices": members, "overhead": overhead, "resume": resume}


if __name__ == "__main__":
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         BENCH_JSON)
    with open(_path, "w") as f:
        json.dump(main(), f, indent=2)
    print(f"wrote {_path}")
