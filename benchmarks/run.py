"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Runs on 8 emulated host devices
(the thesis's research-lab-cluster analogue); set BEFORE jax import."""
import os

if "--one-device" not in __import__("sys").argv:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import json
import sys
import traceback

# make `python benchmarks/run.py` work from anywhere (repo root + src)
_root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _root)
sys.path.insert(0, os.path.join(_root, "src"))


def main() -> None:
    from benchmarks import (batch_grid, core_scaling, fig_5_1_scaling,
                            fig_5_4_matchmaking, fig_5_9_mapreduce,
                            serve_brokers, speedup_model, table_5_1,
                            table_5_2_elastic)
    print("name,us_per_call,derived")
    for mod in (table_5_1, core_scaling, batch_grid, fig_5_1_scaling,
                fig_5_4_matchmaking, fig_5_9_mapreduce, table_5_2_elastic,
                speedup_model, serve_brokers):
        try:
            payload = mod.main()
            # modules that declare a JSON artifact get it written here
            # (core_scaling -> BENCH_core.json: old-vs-new core timings),
            # anchored at the repo root regardless of the invoking CWD
            if payload is not None and getattr(mod, "BENCH_JSON", None):
                path = os.path.join(_root, mod.BENCH_JSON)
                with open(path, "w") as f:
                    json.dump(payload, f, indent=2)
                print(f"# wrote {path}", flush=True)
        except Exception:
            print(f"{mod.__name__},FAILED,", flush=True)
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()
