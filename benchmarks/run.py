"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Runs on 8 emulated host devices
(the thesis's research-lab-cluster analogue); set BEFORE jax import.

``--check`` re-runs only the modules that declare a JSON artifact and FAILS
(exit 1) if any ``scan_s`` entry regressed by more than 20% against the
committed BENCH files — the committed files are left untouched.
"""
import os
import sys

if "--one-device" not in sys.argv:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
if "--check" in sys.argv:
    # regression checks only compare scan_s: skip the slow wave-loop replays
    os.environ.setdefault("BENCH_CORE_WAVE_BUDGET_S", "0")

import json
import traceback

# make `python benchmarks/run.py` work from anywhere (repo root + src)
_root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _root)
sys.path.insert(0, os.path.join(_root, "src"))

REGRESSION_TOLERANCE = 0.20
# entry fields that identify a scan_s measurement across runs
_ID_KEYS = ("core", "n_cloudlets", "n_members", "n_scenarios", "n_vms")


def _scan_entries(obj, out):
    """Collect every ``scan_s`` in a payload, labelled by its identifying
    sibling fields — the committed-vs-fresh join key for ``--check``."""
    if isinstance(obj, dict):
        if "scan_s" in obj:
            label = tuple((k, obj[k]) for k in _ID_KEYS if k in obj)
            out[label] = float(obj["scan_s"])
        for v in obj.values():
            _scan_entries(v, out)
    elif isinstance(obj, list):
        for v in obj:
            _scan_entries(v, out)
    return out


def _check_payload(mod, payload, path):
    """Compare fresh scan_s timings against the committed BENCH file."""
    if not os.path.exists(path):
        return [f"{mod.__name__}: no committed {os.path.basename(path)} "
                f"to check against"]
    with open(path) as f:
        committed = _scan_entries(json.load(f), {})
    fresh = _scan_entries(payload, {})
    problems = []
    for label, old in sorted(committed.items()):
        new = fresh.get(label)
        if new is None:
            continue                     # shrunk sweep: nothing to compare
        if new > old * (1.0 + REGRESSION_TOLERANCE):
            name = ",".join(f"{k}={v}" for k, v in label) or "scan"
            problems.append(f"{os.path.basename(path)}[{name}]: scan_s "
                            f"{old:.4f}s -> {new:.4f}s "
                            f"(+{(new / old - 1) * 100:.0f}%)")
    return problems


def main() -> None:
    from benchmarks import (batch_grid, core_scaling, dist_scaling,
                            fig_5_1_scaling, fig_5_4_matchmaking,
                            fig_5_9_mapreduce, serve_brokers, speedup_model,
                            table_5_1, table_5_2_elastic)
    check = "--check" in sys.argv
    mods = (table_5_1, core_scaling, batch_grid, dist_scaling,
            fig_5_1_scaling, fig_5_4_matchmaking, fig_5_9_mapreduce,
            table_5_2_elastic, speedup_model, serve_brokers)
    if check:
        # only modules whose COMMITTED artifact holds scan_s entries can be
        # compared — skip the rest (e.g. batch_grid's throughput-only JSON)
        # instead of re-running their sweeps for nothing
        def checkable(m):
            path = os.path.join(_root, getattr(m, "BENCH_JSON", "") or "")
            if not getattr(m, "BENCH_JSON", None):
                return False
            if not os.path.exists(path):
                return True          # surfaces the "no committed file" error
            with open(path) as f:
                return bool(_scan_entries(json.load(f), {}))

        mods = [m for m in mods if checkable(m)]
    print("name,us_per_call,derived")
    problems = []
    for mod in mods:
        try:
            payload = mod.main()
            # modules that declare a JSON artifact get it written here
            # (core_scaling -> BENCH_core.json, dist_scaling ->
            # BENCH_dist.json, ...), anchored at the repo root regardless of
            # the invoking CWD; in --check mode the files are compared, not
            # rewritten
            if payload is not None and getattr(mod, "BENCH_JSON", None):
                path = os.path.join(_root, mod.BENCH_JSON)
                if check:
                    problems += _check_payload(mod, payload, path)
                else:
                    with open(path, "w") as f:
                        json.dump(payload, f, indent=2)
                    print(f"# wrote {path}", flush=True)
        except Exception:
            print(f"{mod.__name__},FAILED,", flush=True)
            traceback.print_exc()
            sys.exit(1)
    if check:
        if problems:
            print(f"# REGRESSION: {len(problems)} scan_s timing(s) exceeded "
                  f"the {REGRESSION_TOLERANCE:.0%} budget", flush=True)
            for p in problems:
                print(f"#   {p}", flush=True)
            sys.exit(1)
        print("# check OK: no scan_s regression > "
              f"{REGRESSION_TOLERANCE:.0%}", flush=True)


if __name__ == "__main__":
    main()
