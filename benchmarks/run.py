"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Runs on 8 emulated host devices
(the thesis's research-lab-cluster analogue); set BEFORE jax import."""
import os

if "--one-device" not in __import__("sys").argv:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import sys
import traceback


def main() -> None:
    from benchmarks import (fig_5_1_scaling, fig_5_4_matchmaking,
                            fig_5_9_mapreduce, serve_brokers, speedup_model,
                            table_5_1, table_5_2_elastic)
    print("name,us_per_call,derived")
    for mod in (table_5_1, fig_5_1_scaling, fig_5_4_matchmaking,
                fig_5_9_mapreduce, table_5_2_elastic, speedup_model,
                serve_brokers):
        try:
            mod.main()
        except Exception:
            print(f"{mod.__name__},FAILED,", flush=True)
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()
