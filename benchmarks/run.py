"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Runs on 8 emulated host devices
(the thesis's research-lab-cluster analogue); set BEFORE jax import.

``--check`` FAILS (exit 1) if any ``scan_s`` entry regressed by more than
20% against the committed BENCH files — the committed files are left
untouched.  A suspect module is RE-MEASURED best-of-N (N ≥ 3, via
``BENCH_CHECK_BEST_OF``) before a regression is declared, because single-
shot timings on a shared-CPU box are noisy; every surviving problem names
the BENCH file and entry that tripped.

``--smoke`` runs EVERY benchmark module at toy sizes on 2 emulated devices
without writing any BENCH file — the tier-1 suite invokes it so benchmark
scripts can't silently bit-rot.
"""
import os
import sys

SMOKE = "--smoke" in sys.argv
if SMOKE and "--check" in sys.argv:
    # toy-size labels never join against the committed full-size entries, so
    # the regression gate would pass vacuously with zero comparisons
    sys.exit("--smoke and --check are mutually exclusive: smoke sizes can't "
             "be compared against the committed BENCH files")
if SMOKE:
    # toy sizes everywhere: modules consult benchmarks.common.smoke()
    os.environ["BENCH_SMOKE"] = "1"
    os.environ.setdefault("BENCH_CORE_WAVE_BUDGET_S", "0")
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=2")
elif "--one-device" not in sys.argv:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
if "--check" in sys.argv:
    # regression checks only compare scan_s: skip the slow wave-loop replays
    os.environ.setdefault("BENCH_CORE_WAVE_BUDGET_S", "0")

import json
import traceback

# make `python benchmarks/run.py` work from anywhere (repo root + src)
_root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _root)
sys.path.insert(0, os.path.join(_root, "src"))

REGRESSION_TOLERANCE = 0.20
BEST_OF_N = max(3, int(os.environ.get("BENCH_CHECK_BEST_OF", "3")))
# entry fields that identify a scan_s measurement across runs
_ID_KEYS = ("chunk", "core", "n_cloudlets", "n_members", "n_scenarios",
            "n_vms")


def _scan_entries(obj, out):
    """Collect every ``scan_s`` in a payload, labelled by its identifying
    sibling fields — the committed-vs-fresh join key for ``--check``."""
    if isinstance(obj, dict):
        if "scan_s" in obj:
            label = tuple((k, obj[k]) for k in _ID_KEYS if k in obj)
            out[label] = float(obj["scan_s"])
        for v in obj.values():
            _scan_entries(v, out)
    elif isinstance(obj, list):
        for v in obj:
            _scan_entries(v, out)
    return out


def _compare(committed, fresh, path):
    """Problems for every committed scan_s the fresh (best-of) run exceeds."""
    problems = []
    for label, old in sorted(committed.items()):
        new = fresh.get(label)
        if new is None:
            continue                     # shrunk sweep: nothing to compare
        if new > old * (1.0 + REGRESSION_TOLERANCE):
            name = ",".join(f"{k}={v}" for k, v in label) or "scan"
            problems.append(f"{os.path.basename(path)}[{name}]: scan_s "
                            f"{old:.4f}s -> {new:.4f}s "
                            f"(+{(new / old - 1) * 100:.0f}%)")
    return problems


def _check_payload(mod, payload, path):
    """Compare fresh scan_s timings against the committed BENCH file,
    re-measuring best-of-N before declaring any regression real."""
    if not os.path.exists(path):
        return [f"{mod.__name__}: no committed {os.path.basename(path)} "
                f"to check against"]
    with open(path) as f:
        committed = _scan_entries(json.load(f), {})
    best = _scan_entries(payload, {})
    problems = _compare(committed, best, path)
    attempts = 1
    while problems and attempts < BEST_OF_N:
        # noisy shared-CPU timing: re-run the module and keep the per-entry
        # minimum before believing a regression
        attempts += 1
        print(f"# re-measuring {mod.__name__} "
              f"(attempt {attempts}/{BEST_OF_N}): "
              f"{len(problems)} suspect entr{'y' if len(problems) == 1 else 'ies'}",
              flush=True)
        fresh = _scan_entries(mod.main(), {})
        for label, v in fresh.items():
            best[label] = min(best.get(label, v), v)
        problems = _compare(committed, best, path)
    return [p + f" [best of {attempts}]" for p in problems]


def main() -> None:
    from benchmarks import (batch_grid, checkpoint_resume, core_scaling,
                            dist_scaling, fault_recovery, fig_5_1_scaling,
                            fig_5_4_matchmaking, fig_5_9_mapreduce,
                            kernel_tuning, queue_stats, serve_brokers,
                            serve_load, speedup_model, table_5_1,
                            table_5_2_elastic)
    check = "--check" in sys.argv
    mods = (table_5_1, core_scaling, batch_grid, dist_scaling,
            fig_5_1_scaling, fig_5_4_matchmaking, fig_5_9_mapreduce,
            table_5_2_elastic, speedup_model, serve_brokers, fault_recovery,
            queue_stats, checkpoint_resume, kernel_tuning, serve_load)
    if check:
        # only modules whose COMMITTED artifact holds scan_s entries can be
        # compared — skip the rest (e.g. batch_grid's throughput-only JSON)
        # instead of re-running their sweeps for nothing
        def checkable(m):
            path = os.path.join(_root, getattr(m, "BENCH_JSON", "") or "")
            if not getattr(m, "BENCH_JSON", None):
                return False
            if not os.path.exists(path):
                return True          # surfaces the "no committed file" error
            with open(path) as f:
                return bool(_scan_entries(json.load(f), {}))

        mods = [m for m in mods if checkable(m)]
    print("name,us_per_call,derived")
    problems = []
    for mod in mods:
        try:
            payload = mod.main()
            # modules that declare a JSON artifact get it written here
            # (core_scaling -> BENCH_core.json, dist_scaling ->
            # BENCH_dist.json, ...), anchored at the repo root regardless of
            # the invoking CWD; in --check mode the files are compared (not
            # rewritten) and --smoke never writes at all
            if payload is not None and getattr(mod, "BENCH_JSON", None):
                path = os.path.join(_root, mod.BENCH_JSON)
                if check:
                    problems += _check_payload(mod, payload, path)
                elif not SMOKE:
                    with open(path, "w") as f:
                        json.dump(payload, f, indent=2)
                    print(f"# wrote {path}", flush=True)
        except Exception:
            print(f"{mod.__name__},FAILED,", flush=True)
            traceback.print_exc()
            sys.exit(1)
    if check:
        if problems:
            print(f"# REGRESSION: {len(problems)} scan_s timing(s) exceeded "
                  f"the {REGRESSION_TOLERANCE:.0%} budget after best-of-"
                  f"{BEST_OF_N} re-measurement", flush=True)
            for p in problems:
                print(f"#   {p}", flush=True)
            sys.exit(1)
        print("# check OK: no scan_s regression > "
              f"{REGRESSION_TOLERANCE:.0%} (best-of-{BEST_OF_N})", flush=True)
    if SMOKE:
        print("# smoke OK: every benchmark module ran at toy sizes "
              "(no BENCH files written)", flush=True)


if __name__ == "__main__":
    main()
