"""Figs 5.9–5.11 — MapReduce word count: Hazelcast-style vs Infinispan-style
backends, scaling size (reduce invocations) and member count (map
invocations = files)."""
import jax

from benchmarks.common import emit, mesh_of, smoke
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job


def main():
    n_devs = len(jax.devices())
    ns = [n for n in (1, 2, 4, 8) if n <= n_devs]
    if smoke():
        sweep, scale_job = [(256, 512)], (256, 1024)
    else:
        sweep = [(1024, 4096), (4096, 16384), (16384, 65536)]
        scale_job = (8192, 32768)
    # Fig 5.9: size sweep on 1 member, both backends
    for vocab, file_len in sweep:
        corpus = make_corpus(8, file_len, vocab)   # host array:
        # the dispatcher slices chunks host-side, so a device
        # corpus would only add a D2H round-trip per run
        for backend in ("hazelcast", "infinispan"):
            eng = MapReduceEngine(mesh_of(1), backend=backend)
            _, secs = eng.benchmark(word_count_job(vocab), corpus, repeats=3)
            emit(f"f5.9/{backend}/reduce{vocab}", secs * 1e6,
                 f"map_inv=8;reduce_inv={vocab}")
    # Figs 5.10/5.11: member scaling, fixed job
    vocab, file_len = scale_job
    corpus = make_corpus(8, file_len, vocab)
    for backend in ("hazelcast", "infinispan"):
        for n in ns:
            eng = MapReduceEngine(mesh_of(n), backend=backend)
            _, secs = eng.benchmark(word_count_job(vocab), corpus, repeats=3)
            emit(f"f5.10/{backend}/n{n}", secs * 1e6, "map_inv=8")


if __name__ == "__main__":
    main()
