"""Serving analogue of §5.1.2: round-robin vs matchmaking request schedulers
under a mixed workload (utilization + steps to drain)."""
import numpy as np

import jax

from benchmarks.common import emit, smoke
from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serve.scheduler import Request, ServeEngine


def main():
    n_reqs, max_steps = (4, 48) if smoke() else (10, 128)
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=128)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, 128, size=int(rng.integers(2, 10))).astype(
        np.int32), int(rng.integers(2, 6))) for _ in range(n_reqs)]
    for policy in ("round_robin", "matchmaking"):
        eng = ServeEngine(model, params, n_slots=4, max_len=48, policy=policy)
        for i, (p, m) in enumerate(reqs):
            eng.sched.submit(Request(i, p, max_new_tokens=m))
        out = eng.run(max_steps=max_steps)
        emit(f"serve/{policy}", float(out["steps"]),
             f"completed={len(out['completed'])};dropped={out['dropped']}")


if __name__ == "__main__":
    main()
