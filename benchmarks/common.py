"""Shared benchmark plumbing. Benchmarks run on 8 emulated host devices (set
before jax import by benchmarks/run.py) — the thesis's 6-node i7 cluster
analogue; ``run.py --smoke`` flips every module to toy sizes on 2 devices."""
import os
import time

import jax
import numpy as np
from jax.sharding import Mesh


def smoke() -> bool:
    """True when running under ``benchmarks/run.py --smoke``: every module
    shrinks to toy sizes so the whole suite exercises its code paths in
    seconds (a tier-1 test invokes it — benchmark scripts can't bit-rot)."""
    return os.environ.get("BENCH_SMOKE") == "1"


def mesh_of(n: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:n]), ("data",))


def timed(fn, *args, repeats=3, warmup=1, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats, out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
